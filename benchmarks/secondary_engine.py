"""Figures 25-27 on the REAL engine: secondary-index maintenance
(eager vs lazy) and the component-count write controller, measured on a
multi-tree ``StorageGroup`` instead of the fluid simulator — the
ROADMAP's last simulator-only evaluation stood up on the data plane.

Three experiments:

* Ingestion (fig 25): two-phase testing measures max write throughput
  for a plain engine, a lazy-indexed group and an eager-indexed group
  (one secondary tree each, sharing the pump budget).  Lazy appends one
  index entry per put; eager reads the old value through the fused
  probe and writes delete+insert — more index traffic per put, so its
  background-bound maximum is lower.
* Index reads (fig 26's other half): after identical loads compacted
  to one run per tree, batched ``index_lookup``/``index_scan``
  wall-clock — the eager index answers from its own tree (covering);
  lazy validates every candidate against the primary, paying a second
  probe.
* Write controller (fig 27): the eager system re-runs its running phase
  under ``cap(t) = C / (1 + b*n_components + c*[merging])`` through
  ``EngineSystem.write_controller``; utilization sweep shows bounded
  tails at ~80% and degradation toward 95%.

Sim agreement (the PR-4 validation idiom): the same qualitative
orderings are recomputed on the fluid simulator (``fig25_27_secondary``
machinery) and must match the engine's.
"""
from __future__ import annotations

import math
import time

import numpy as np

from repro.core.constraints import GlobalConstraint
from repro.core.engine import IndexSpec, LSMEngine
from repro.core.policies import TieringPolicy
from repro.core.scheduler import FairScheduler, make_scheduler
from repro.core.sim import ClosedClient
from repro.core.twophase import EngineSystem, run_two_phase

from .common import save
from .fig25_27_secondary import _eager_controller, _sim

MEMTABLE = 256
UNIQUE = 1 << 14
BANDWIDTH = 4096 * 1024        # 4096 entries/s of background I/O
MEM_RATE = 8000.0
ATTR_SPACE = 1 << 20


def _factory(mode: str | None, scheduler: str = "fair"):
    def factory() -> LSMEngine:
        pol = TieringPolicy(3, MEMTABLE, UNIQUE)
        cons = GlobalConstraint(2 * pol.expected_components())
        idx = () if mode is None else (IndexSpec("ix", mode=mode),)
        return LSMEngine(pol, make_scheduler(scheduler), cons,
                         memtable_entries=MEMTABLE, unique_keys=UNIQUE,
                         merge_block=64, indexes=idx)
    return factory


def _system(mode, scheduler="fair", controller=None,
            tick_s=0.02) -> EngineSystem:
    return EngineSystem(_factory(mode, scheduler),
                        bandwidth_bytes_per_s=BANDWIDTH,
                        mem_write_rate=MEM_RATE, tick_s=tick_s,
                        key_space=UNIQUE, write_controller=controller)


def _engine_controller(base_rate: float):
    """The fig-27 law on the real engine: lookup-bound eager ingestion
    slows with live component count (across ALL trees of the group) and
    with ongoing merge activity."""
    def ctrl(t, eng):
        n = eng.num_components()
        merging = any(tr.running for tr in eng.trees)
        return base_rate / (1.0 + 0.06 * n + 0.5 * merging)
    return ctrl


def _load_group(mode: str, n: int, seed: int = 0) -> LSMEngine:
    eng = _factory(mode)()
    rng = np.random.default_rng(seed)
    keys = rng.permutation(n).astype(np.uint32)
    vals = rng.integers(0, ATTR_SPACE, n, dtype=np.int32)
    done = 0
    while done < n:
        done += eng.put_batch(keys[done:], vals[done:])
        eng.pump(1 << 12)
    eng.drain()
    # compact to one run per tree: fig 26 compares the steady-state read
    # cost of validation, not transient component-count differences
    # (eager's delete+insert traffic leaves more runs after the load)
    eng.compact_all()
    return eng, vals


def _time_reads(eng, attrs, reps: int) -> dict:
    qs = [attrs[i::reps].astype(np.uint32) for i in range(reps)]
    for q in qs:                  # warm caches AND the per-shape JIT —
        eng.index_lookup("ix", q)  # qs carries two distinct batch sizes
    eng.index_scan("ix", 0, ATTR_SPACE)
    lookup_s = scan_s = float("inf")
    for _ in range(3):            # best-of-3: shared-box noise
        t0 = time.perf_counter()
        for q in qs:
            eng.index_lookup("ix", q)
        lookup_s = min(lookup_s, time.perf_counter() - t0)
        t0 = time.perf_counter()
        for _ in range(max(reps // 4, 1)):
            eng.index_scan("ix", 0, ATTR_SPACE)
        scan_s = min(scan_s, time.perf_counter() - t0)
    return {"lookup_s": lookup_s, "scan_s": scan_s}


def run(quick: bool = False) -> dict:
    t_test, t_run, warm = (6.0, 8.0, 1.0) if quick else (12.0, 20.0, 2.0)
    out: dict = {"claims": {}}

    # -- fig 25: ingestion, two-phase testing per maintenance mode ------
    maxes: dict[str, float] = {}
    p99s: dict[str, float] = {}
    for mode in (None, "lazy", "eager"):
        name = mode or "plain"
        res = run_two_phase(testing_system=lambda: _system(mode),
                            running_system=lambda: _system(mode, "greedy"),
                            testing_duration=t_test,
                            running_duration=t_run, warmup=warm)
        maxes[name] = res.max_throughput
        p99s[name] = res.write_latencies.get(99)
    out["max_throughput"] = maxes
    out["running_p99"] = p99s

    # -- fig 26: index-read latency, identical loads --------------------
    n_load = 2048 if quick else 8192
    reps = 8 if quick else 32
    reads = {}
    for mode in ("eager", "lazy"):
        eng, vals = _load_group(mode, n_load)
        attrs = np.unique(vals.astype(np.uint32))
        reads[mode] = _time_reads(eng, attrs, reps)
        reads[mode]["index_entries"] = eng.trees[1].total_entries()
    out["index_reads"] = reads

    # -- fig 27: utilization sweep under the write controller -----------
    ctrl_base = maxes["lazy"] * 1.3
    res_c = run_two_phase(
        testing_system=lambda: _system(
            "eager", controller=_engine_controller(ctrl_base)),
        running_system=lambda: _system(
            "eager", "greedy", controller=_engine_controller(ctrl_base)),
        testing_duration=t_test, running_duration=t_run, warmup=warm)
    eager_ctrl_max = res_c.max_throughput
    out["eager_controlled_max"] = eager_ctrl_max
    utils = [0.6, 0.8, 0.95]
    sweep, stalls = [], []
    for u in utils:
        sys_u = _system("eager", "greedy",
                        controller=_engine_controller(ctrl_base))
        res_u = run_two_phase(
            testing_system=lambda: _system(
                "eager", controller=_engine_controller(ctrl_base)),
            running_system=lambda: sys_u,
            utilization=u, testing_duration=t_test,
            running_duration=t_run, warmup=warm)
        sweep.append(res_u.write_latencies.get(99))
        stalls.append(len(res_u.running.stalls))
    out["utilizations"] = utils
    out["eager_p99_by_utilization"] = sweep
    out["eager_stalls_by_utilization"] = stalls

    # -- sim agreement (PR-4 idiom): same orderings on the fluid model --
    sim_test = 1800.0 if quick else 3600.0
    lazy_sim = _sim(FairScheduler()).run(ClosedClient(), sim_test)
    sim_lazy_max = lazy_sim.throughput(t_from=300.0)
    eager_sim = _sim(FairScheduler(),
                     controller=_eager_controller(sim_lazy_max * 1.3)) \
        .run(ClosedClient(), sim_test)
    sim_eager_max = eager_sim.throughput(t_from=300.0)
    out["sim"] = {"lazy_max": sim_lazy_max, "eager_max": sim_eager_max}

    c = out["claims"]
    # directional, not margin-gated: the virtual clock charges only
    # modeled I/O, so eager's extra read-old-value probe CPU is free and
    # the engine's gap is structurally thinner than the sim's (the
    # delete+insert index traffic still shows).  The grid is
    # deterministic (virtual clock), so strict < is reproducible.
    c["lazy_ingests_faster_than_eager"] = \
        maxes["eager"] < maxes["lazy"]
    c["index_maintenance_costs_ingest"] = \
        maxes["lazy"] <= maxes["plain"] * 1.05
    c["eager_reads_faster_than_lazy"] = \
        reads["eager"]["lookup_s"] < reads["lazy"]["lookup_s"]
    c["covering_scan_faster_than_validated"] = \
        reads["eager"]["scan_s"] < reads["lazy"]["scan_s"]
    c["controller_bounds_tail_at_80"] = \
        sweep[utils.index(0.8)] < 0.2 * sweep[-1] + 5.0
    c["p99_finite_every_mode"] = all(
        math.isfinite(v) for v in p99s.values())
    c["sim_agreement_eager_slower"] = \
        (sim_eager_max < sim_lazy_max) and \
        (maxes["eager"] < maxes["lazy"])
    save("secondary_engine", out)
    return out


if __name__ == "__main__":
    print(run(quick=True)["claims"])
