"""Figure 11: size-ratio sweep (T = 2..10).

Max throughput rises with T under tiering, falls under leveling (with
the dynamic-level-size fix [31]).  Greedy keeps p99 small everywhere;
fair's p99 grows with T under leveling.
"""
from __future__ import annotations

from repro.core.twophase import run_two_phase

from .common import durations, make_system, save


def run(quick: bool = False) -> dict:
    test_s, run_s, warm = durations(quick)
    ratios = [2, 4, 10] if quick else [2, 3, 4, 6, 8, 10]
    out: dict = {"ratios": ratios, "claims": {}}
    for policy in ("tiering", "leveling"):
        pol_kw = {"dynamic_level_size": True} if policy == "leveling" else {}
        tp, p99f, p99g = [], [], []
        for T in ratios:
            resf = run_two_phase(
                testing_system=make_system(policy, "fair", size_ratio=T,
                                           **pol_kw),
                testing_duration=test_s, running_duration=run_s,
                warmup=warm)
            resg = run_two_phase(
                testing_system=make_system(policy, "fair", size_ratio=T,
                                           **pol_kw),
                running_system=make_system(policy, "greedy", size_ratio=T,
                                           **pol_kw),
                testing_duration=test_s, running_duration=run_s,
                warmup=warm)
            tp.append(resf.max_throughput)
            p99f.append(resf.write_latencies[99])
            p99g.append(resg.write_latencies[99])
        out[policy] = {"max_throughput": tp, "fair_p99": p99f,
                       "greedy_p99": p99g}
    out["claims"]["tiering_throughput_increases_with_T"] = \
        out["tiering"]["max_throughput"][-1] > \
        out["tiering"]["max_throughput"][0]
    out["claims"]["leveling_throughput_decreases_with_T"] = \
        out["leveling"]["max_throughput"][-1] < \
        out["leveling"]["max_throughput"][0]
    out["claims"]["greedy_p99_small_all_ratios"] = \
        max(out["tiering"]["greedy_p99"] + out["leveling"]["greedy_p99"]) < 10
    out["claims"]["leveling_fair_p99_grows"] = \
        out["leveling"]["fair_p99"][-1] > \
        max(out["leveling"]["greedy_p99"][-1] * 2, 1.0)
    save("fig11_size_ratio", out)
    return out
