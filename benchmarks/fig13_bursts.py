"""Figure 13: bursty arrivals — processing writes as quickly as possible
(Theorem 1) beats a rate-limited writer on write latency, even though
the limiter avoids stalls."""
from __future__ import annotations

from repro.core.sim import BurstyArrival, OpenClient

from .common import durations, make_system, save


def run(quick: bool = False) -> dict:
    _, run_s, _ = durations(quick)
    run_s = max(run_s, 3600.0) if not quick else run_s
    # paper: 2000/s for 25 min, 8000/s for 5 min; scaled 10x down in time
    # for quick mode
    scale = 0.2 if quick else 1.0
    arr = BurstyArrival(2000.0 / 10, 8000.0 / 10,
                        1500.0 * scale, 300.0 * scale)

    def run_one(limit: bool):
        sim = make_system("leveling", "greedy", size_ratio=10)()
        if limit:
            sim.controller = lambda t, tree: 400.0  # 4000/s scaled by 10
        tr = sim.run(OpenClient(arr), run_s)
        return {"write_p99_s": tr.write_latency_percentiles((99,))[99],
                "stall_time_s": tr.stall_time(), "n_stalls": len(tr.stalls)}

    no_limit = run_one(False)
    limit = run_one(True)
    out = {
        "no_limit": no_limit, "limit": limit,
        "claims": {
            "limiter_avoids_stalls": limit["stall_time_s"] <=
                no_limit["stall_time_s"] + 1e-9,
            "asap_lower_write_latency":
                no_limit["write_p99_s"] < limit["write_p99_s"],
        },
    }
    save("fig13_bursts", out)
    return out
