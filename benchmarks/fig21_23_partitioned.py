"""Figures 21/23: LevelDB-style partitioned merges.  The score-based
merge-everything-at-L0 behaviour over-reports the max (~unsustainable);
merging exactly T0 runs in the testing phase gives a lower (~30% in the
paper) but sustainable rate under the single-threaded scheduler."""
from __future__ import annotations

from repro.core.twophase import run_two_phase

from .common import MEMTABLE, UNIQUE, durations, make_system, save


def _kw(merge_all: bool, selection: str = "round_robin"):
    # L1 base = 20x memtable: calibrated so the L1-rewrite amortization
    # the paper measures (~30% throughput gap, Figure 23 vs 21) is visible
    # in the fluid model at our 10x-scaled event counts.
    return dict(file_entries=MEMTABLE / 2, l1_capacity=MEMTABLE * 20,
                l0_min_merge=4, l0_merge_all=merge_all, selection=selection)


def run(quick: bool = False) -> dict:
    test_s, run_s, warm = durations(quick)
    broken = run_two_phase(
        testing_system=make_system("partitioned", "single", size_ratio=10,
                                   constraint="l0", **_kw(True)),
        testing_duration=test_s, running_duration=run_s, warmup=warm)
    fixed = run_two_phase(
        testing_system=make_system("partitioned", "single", size_ratio=10,
                                   constraint="l0", **_kw(False)),
        running_system=make_system("partitioned", "single", size_ratio=10,
                                   constraint="l0", **_kw(True)),
        testing_duration=test_s, running_duration=run_s, warmup=warm)
    # selection strategy has little impact (uniform updates)
    rr = fixed
    cb = run_two_phase(
        testing_system=make_system("partitioned", "single", size_ratio=10,
                                   constraint="l0",
                                   **_kw(False, "choose_best")),
        running_system=make_system("partitioned", "single", size_ratio=10,
                                   **_kw(True, "choose_best"),
                                   constraint="l0"),
        testing_duration=test_s, running_duration=run_s, warmup=warm)
    out = {
        "broken": {"max_tp": broken.max_throughput,
                   "write_p99_s": broken.write_latencies[99],
                   "stall_s": broken.running.stall_time()},
        "fixed": {"max_tp": fixed.max_throughput,
                  "write_p99_s": fixed.write_latencies[99],
                  "stall_s": fixed.running.stall_time()},
        "choose_best_max_tp": cb.max_throughput,
        "claims": {
            "naive_max_unsustainable":
                broken.running.stall_time() > 10.0 or
                broken.write_latencies[99] > 10.0,
            "exact_t0_lower_max":
                fixed.max_throughput < 0.9 * broken.max_throughput,
            "exact_t0_sustainable": fixed.write_latencies[99] < 10.0,
            "selection_strategy_minor":
                abs(cb.max_throughput - rr.max_throughput) <
                0.15 * rr.max_throughput,
        },
    }
    save("fig21_23_partitioned", out)
    return out
