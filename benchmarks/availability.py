"""Availability benchmark: online recovery and the fault-tolerance
plane.

Four scenarios, one claim each (the PR's acceptance bars):

- **TTFR vs full recovery** — an online ``RecoverySession`` serves its
  first read at epoch 0 (time-to-first-read is the session open), while
  full recovery takes many budgeted epochs: availability returns
  orders-of-magnitude before durability catches up.  Reader/writer
  latencies are sampled DURING replay and reported as p50/p99 next to
  the post-recovery baseline.
- **Budget sweep** — starving the pump budget slows time-to-FULL-
  recovery roughly in proportion, but time-to-first-read stays at
  epoch 0 for every budget: replay is arbitrated I/O, serving is not
  gated on it.
- **Scrub repair** — an injected bit-flip in a live SSTable is
  detected by the budget-charged scrub pass, the table quarantined and
  repaired from the snapshot store, and reads return bit-identical
  answers afterwards.
- **ENOSPC stall-and-drain** — with the disk full, writes stall (a
  counted constraint stall, not an error, not data loss); when space
  returns the stalled traffic drains completely.

Recovery "time" is virtual: epochs at a fixed per-epoch I/O budget,
the same unit the background scheduler meters everywhere else.
"""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

import numpy as np

from repro.checkpoint import EngineSnapshotStore
from repro.core import (FaultInjector, IOStack, LSMEngine, RecoverySession,
                        RetryPolicy, WriteAheadLog, apply_torn_tail,
                        flip_bit)
from repro.core.constraints import GlobalConstraint
from repro.core.policies import LevelingPolicy
from repro.core.scheduler import GreedyScheduler

from .common import save


def _engine(tmp: Path, unique: int, memtable: int, tag: str,
            wal: bool = True, faults=None, **kw) -> LSMEngine:
    io = IOStack(faults, RetryPolicy(backoff_s=1e-4, backoff_cap_s=1e-3),
                 sleep=lambda s: None)
    w = WriteAheadLog(tmp / f"wal-{tag}", io=io) if wal else None
    return LSMEngine(LevelingPolicy(3, memtable, unique), GreedyScheduler(),
                     GlobalConstraint(400), memtable_entries=memtable,
                     unique_keys=unique, use_kernels=False,
                     scan_use_kernels=False, wal=w, faults=faults, **kw)


def _feed(eng: LSMEngine, keys, vals, pump: int = 1 << 12) -> None:
    done = 0
    while done < len(keys):
        done += eng.put_batch(keys[done:], vals[done:])
        if done < len(keys):
            eng.pump(pump)


def _crashed_workload(tmp: Path, unique: int, memtable: int, n: int,
                      tag: str, seed: int = 0):
    """Load n entries (snapshot at the half-way point), then crash with
    half the unsynced tail torn.  Returns the snapshot store."""
    eng = _engine(tmp, unique, memtable, tag)
    store = EngineSnapshotStore(tmp / f"snap-{tag}")
    rng = np.random.default_rng(seed)
    for off in range(0, n, 512):
        m = min(512, n - off)
        _feed(eng, rng.integers(0, unique, m, dtype=np.uint32),
              rng.integers(0, 1 << 30, m, dtype=np.int32))
        eng.pump(256)
        if off == (n // 1024) * 512:
            eng.snapshot(store)
    apply_torn_tail(eng.wal, 0.5)
    return store


def _percentiles(xs) -> dict:
    if not xs:
        return {"p50_us": 0.0, "p99_us": 0.0}
    a = np.asarray(xs) * 1e6
    return {"p50_us": float(np.percentile(a, 50)),
            "p99_us": float(np.percentile(a, 99))}


def run(quick: bool = False) -> dict:
    unique = 4096
    memtable = 256
    n = 4_000 if quick else 16_000
    budget = 256                         # per-epoch replay/serving budget
    result: dict = {"quick": quick}

    with tempfile.TemporaryDirectory() as td:
        tmp = Path(td)

        # -- TTFR vs time-to-full-recovery, tails during replay -------------
        store = _crashed_workload(tmp, unique, memtable, n, "ttfr")
        eng = _engine(tmp, unique, memtable, "ttfr")
        t0 = time.perf_counter()
        sess = RecoverySession(eng, store, online=True)
        probe = np.arange(0, unique, 61, dtype=np.uint32)
        f, _ = eng.get_batch(probe)      # the first read: zero epochs in
        ttfr_s = time.perf_counter() - t0
        ttfr_found = int(f.sum())
        rng = np.random.default_rng(1)
        r_lat, w_lat = [], []
        epochs = 0
        while not sess.done and epochs < 1_000_000:
            eng.pump(budget)
            epochs += 1
            q = rng.integers(0, unique, 16, dtype=np.uint32)
            t = time.perf_counter()
            eng.get_batch(q)
            r_lat.append(time.perf_counter() - t)
            t = time.perf_counter()
            eng.put_batch(q, np.ones(16, np.int32))
            w_lat.append(time.perf_counter() - t)
        full_epochs = epochs
        eng.pump(1 << 20)
        rs, ws = [], []
        for _ in range(200):             # post-recovery baseline tails
            q = rng.integers(0, unique, 16, dtype=np.uint32)
            t = time.perf_counter()
            eng.get_batch(q)
            rs.append(time.perf_counter() - t)
            t = time.perf_counter()
            eng.put_batch(q, np.ones(16, np.int32))
            ws.append(time.perf_counter() - t)
        result["online"] = {
            "ttfr_epochs": 0, "ttfr_wall_s": ttfr_s,
            "ttfr_keys_found": ttfr_found,
            "time_to_full_recovery_epochs": full_epochs,
            "replayed_entries": sess.total,
            "reader_during_replay": _percentiles(r_lat),
            "writer_during_replay": _percentiles(w_lat),
            "reader_steady_state": _percentiles(rs),
            "writer_steady_state": _percentiles(ws),
        }

        # -- budget sweep: starved replay vs first read ---------------------
        budgets = (64, 256, 1024)
        sweep = {}
        for b in budgets:
            st = _crashed_workload(tmp, unique, memtable, n, f"b{b}")
            e2 = _engine(tmp, unique, memtable, f"b{b}")
            s2 = RecoverySession(e2, st, online=True)
            f, _ = e2.get_batch(probe)   # served before ANY replay budget
            ep = 0
            while not s2.done and ep < 1_000_000:
                e2.pump(b)
                ep += 1
            sweep[str(b)] = {"full_recovery_epochs": ep,
                             "first_read_epochs": 0,
                             "first_read_keys_found": int(f.sum())}
        result["budget_sweep"] = sweep

        # -- scrub: detect + repair an injected bit-flip --------------------
        eng = _engine(tmp, unique, memtable, "scrub")
        rng = np.random.default_rng(3)
        _feed(eng, rng.integers(0, unique, n // 2, dtype=np.uint32),
              rng.integers(0, 1 << 30, n // 2, dtype=np.int32))
        eng.pump(1 << 20)
        st = EngineSnapshotStore(tmp / "snap-scrub")
        eng.snapshot(st)
        keys = np.arange(unique, dtype=np.uint32)
        before_f, before_v = eng.get_batch(keys)
        sc = eng.enable_scrub(store=st, entries_per_epoch=budget)
        flip_bit(eng.trees[0]._order[0], entry=2, bit=11)
        ep = 0
        while not sc.stats["tables_repaired"] and ep < 10_000:
            eng.pump(budget)
            ep += 1
        after_f, after_v = eng.get_batch(keys)
        result["scrub"] = {
            "epochs_to_repair": ep,
            "bit_identical_after_repair":
                bool(np.array_equal(before_f, after_f)
                     and np.array_equal(before_v[before_f],
                                        after_v[after_f])),
            **sc.stats,
        }

        # -- ENOSPC: stall, then drain when space returns -------------------
        fi = FaultInjector()
        eng = _engine(tmp, unique, memtable, "enospc", faults=fi)
        _feed(eng, np.arange(512, dtype=np.uint32),
              np.ones(512, np.int32))
        eng.pump(1 << 20)                # memtable room: the next put's
                                         # refusal is the DISK, not RAM
        fi.arm_io("io-write", error="ENOSPC", every=1, count=None)
        k = np.arange(512, 1024, dtype=np.uint32)
        stalled = eng.put_batch(k, np.full(512, 9, np.int32))
        stall_epochs = 0
        for _ in range(8):               # pumping while full: no crash
            eng.pump(budget)
            stall_epochs += 1
        h_full = eng.health()
        fi.disarm("io-write")            # space returns
        done = 0
        while done < len(k):
            done += eng.put_batch(k[done:], np.full(len(k) - done, 9,
                                                    np.int32))
            if done < len(k):
                eng.pump(1 << 12)
        eng.pump(1 << 20)
        f, v = eng.get_batch(k)
        result["enospc"] = {
            "admitted_while_full": int(stalled),
            "enospc_stalls": h_full["enospc_stalls"],
            "stall_events": eng.stats["stall_events"],
            "drained_after_space_returned": int(done),
            "all_reads_correct_after_drain":
                bool(f.all() and (v == 9).all()),
        }

    sweeps = [sweep[str(b)]["full_recovery_epochs"] for b in budgets]
    result["claims"] = {
        "first_read_precedes_full_recovery":
            result["online"]["ttfr_epochs"] == 0 and full_epochs > 10,
        "starved_budget_slows_full_recovery_not_first_read":
            sweeps[0] > sweeps[1] > sweeps[2]
            and all(sweep[str(b)]["first_read_epochs"] == 0
                    for b in budgets),
        "scrub_detects_and_repairs_bit_flip":
            result["scrub"]["tables_repaired"] == 1
            and result["scrub"]["bit_identical_after_repair"],
        "enospc_stalls_then_drains":
            result["enospc"]["admitted_while_full"] == 0
            and result["enospc"]["enospc_stalls"] >= 1
            and result["enospc"]["drained_after_space_returned"] == 512
            and result["enospc"]["all_reads_correct_after_drain"],
    }
    save("availability", result)
    return result


if __name__ == "__main__":
    import json
    print(json.dumps(run(quick=True)["claims"], indent=1))
