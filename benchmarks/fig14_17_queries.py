"""Figures 14-17 (analog): query performance under concurrent updates,
on the REAL engine (Pallas bloom probes + sorted searches) instead of
the fluid model.

Point lookups and short scans are sensitive to the number of live
components; the greedy scheduler minimizes that count, so its query
throughput dominates fair's — more so under tiering (more components)
than leveling, exactly the paper's Figure 14/16 structure.  The range
workload (Figures 15/17) runs on the real ``scan_range`` plane — each
scan is one k-way newest-wins merge over every live run's window — and
is checked against the tracked write history, so the figure replay
doubles as a differential test of the scan plane under live merges.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.constraints import GlobalConstraint
from repro.core.engine import LSMEngine
from repro.core.policies import LevelingPolicy, TieringPolicy
from repro.core.scheduler import FairScheduler, GreedyScheduler

from .common import save

UNIQUE = 16_384
MEMTABLE = 512


SCAN_SPAN = 512               # short range scans, Figures 15/17


def _run_engine(policy_name: str, sched, n_ops: int, rng):
    if policy_name == "tiering":
        pol = TieringPolicy(3, MEMTABLE, UNIQUE)
    else:
        pol = LevelingPolicy(4, MEMTABLE, UNIQUE)
    eng = LSMEngine(pol, sched, GlobalConstraint(64),
                    memtable_entries=MEMTABLE, unique_keys=UNIQUE,
                    use_kernels=True, merge_block=128)
    ref = {}                  # shadow history: scans double as a diff test
    comps_seen = []
    lookup_cost = []          # components probed per lookup batch
    scan_cost = []            # live components per range scan
    scan_entries = 0
    for i in range(n_ops):
        k = int(rng.integers(0, UNIQUE))
        while not eng.put(k, i):
            eng.pump(MEMTABLE)
        ref[k] = i
        if i % 32 == 0:
            eng.pump(MEMTABLE // 2)
        if i % 256 == 0:
            comps_seen.append(eng.num_components())
            # point-lookup batch: cost proxy = bloom probes + searches
            keys = rng.integers(0, UNIQUE, 16)
            for q in keys:
                eng.get(int(q))
            lookup_cost.append(eng.num_components())
            # short range scans on the REAL scan plane (one k-way merge
            # over every live run's window), mid-merge
            lo = int(rng.integers(0, UNIQUE - SCAN_SPAN))
            sk, sv = eng.scan_range(lo, lo + SCAN_SPAN)
            scan_entries += len(sk)
            scan_cost.append(eng.num_components())
            want = {k: v for k, v in ref.items() if lo <= k < lo + SCAN_SPAN}
            assert dict(zip(sk.tolist(), sv.tolist())) == want, \
                (policy_name, sched.name, i)
    return {
        "mean_components": float(np.mean(comps_seen)),
        "max_components": int(np.max(comps_seen)),
        "mean_lookup_components": float(np.mean(lookup_cost)),
        "mean_scan_components": float(np.mean(scan_cost)),
        "scan_entries": int(scan_entries),
        "bloom_skips": eng.stats["bloom_skips"],
        "merges": eng.stats["merges"],
    }


def run(quick: bool = False) -> dict:
    n_ops = 4_000 if quick else 12_000
    out: dict = {"claims": {}}
    for policy in ("tiering", "leveling"):
        row = {}
        for sname, sched in (("fair", FairScheduler()),
                             ("greedy", GreedyScheduler())):
            rng = np.random.default_rng(7)
            row[sname] = _run_engine(policy, sched, n_ops, rng)
        out[policy] = row
    c = out["claims"]
    c["greedy_fewer_components_tiering"] = (
        out["tiering"]["greedy"]["mean_components"] <=
        out["tiering"]["fair"]["mean_components"] + 1e-9)
    c["greedy_fewer_components_leveling"] = (
        out["leveling"]["greedy"]["mean_components"] <=
        out["leveling"]["fair"]["mean_components"] + 1e-9)
    # tiering benefits more from greedy (more components to reduce)
    gain_t = out["tiering"]["fair"]["mean_components"] - \
        out["tiering"]["greedy"]["mean_components"]
    gain_l = out["leveling"]["fair"]["mean_components"] - \
        out["leveling"]["greedy"]["mean_components"]
    c["tiering_benefits_more"] = gain_t >= gain_l - 0.5
    c["leveling_fewer_components_than_tiering"] = (
        out["leveling"]["fair"]["mean_components"] <
        out["tiering"]["fair"]["mean_components"])
    # range scans (Fig 15/17): cost tracks live components, so greedy's
    # scan cost cannot exceed fair's under tiering
    c["greedy_scan_cost_leq_fair_tiering"] = (
        out["tiering"]["greedy"]["mean_scan_components"] <=
        out["tiering"]["fair"]["mean_scan_components"] + 1e-9)
    save("fig14_17_queries", out)
    return out
