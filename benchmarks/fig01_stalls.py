"""Figure 1: writing as fast as possible periodically stalls.

Closed-system client over a partitioned (RocksDB-like) LSM-tree: the
instantaneous write throughput collapses periodically once merges lag.
"""
from __future__ import annotations

import numpy as np

from repro.core.sim import ClosedClient

from .common import MEMTABLE, durations, make_system, save


def run(quick: bool = False) -> dict:
    test_s, _, warm = durations(quick)
    sim = make_system("partitioned", "single", constraint="l0",
                      size_ratio=10, file_entries=MEMTABLE / 2,
                      l1_capacity=MEMTABLE * 10)()
    tr = sim.run(ClosedClient(n_threads=8), test_s)
    t, w = tr.windowed_throughput(30.0)
    w_late = w[t > warm]
    cv = float(np.std(w_late) / max(np.mean(w_late), 1e-9))
    result = {
        "throughput_mean": float(np.mean(w_late)),
        "throughput_cv": cv,
        "n_stalls": len(tr.stalls),
        "stall_time_s": tr.stall_time(),
        "claims": {
            "periodic_stalls_or_high_variance":
                len(tr.stalls) > 3 or cv > 0.3,
        },
    }
    save("fig01_stalls", result)
    return result
