"""Roofline table generator: reads artifacts/dryrun/*.json (produced by
``python -m repro.launch.dryrun --all``) and emits the per-(arch x shape
x mesh) three-term roofline, dominant bottleneck, and useful-flops ratio
— the source of EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import json
from pathlib import Path

from .common import save

DRYRUN = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


def load_cells(mesh: str | None = None) -> list[dict]:
    cells = []
    for p in sorted(DRYRUN.glob("*.json")):
        d = json.loads(p.read_text())
        if mesh and d.get("mesh") != mesh:
            continue
        cells.append(d)
    return cells


def table(mesh: str = "single") -> list[dict]:
    rows = []
    for d in load_cells(mesh):
        if d.get("status") == "skipped":
            rows.append({"arch": d["arch"], "shape": d["shape"],
                         "status": "skipped",
                         "why": d.get("skip_reason", "")[:60]})
            continue
        if d.get("status") != "ok":
            rows.append({"arch": d["arch"], "shape": d["shape"],
                         "status": "error"})
            continue
        r = d["roofline"]
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "status": "ok",
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "dominant": r["dominant"],
            "roofline_frac": r["compute_s"] / bound if bound else 0.0,
            "useful_flops_ratio": d.get("useful_flops_ratio"),
            "model_flops": d.get("model_flops_6nd"),
        })
    return rows


def fmt_row(r: dict) -> str:
    if r["status"] != "ok":
        return (f"| {r['arch']} | {r['shape']} | {r['status']} "
                f"| - | - | - | - | - |")
    u = r["useful_flops_ratio"]
    return (f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} "
            f"| {r['memory_s']:.3g} | {r['collective_s']:.3g} "
            f"| {r['dominant']} | {r['roofline_frac']:.2f} "
            f"| {u:.2f} |" if u else "| ? |")


def run(quick: bool = False) -> dict:
    rows = table("single")
    ok = [r for r in rows if r["status"] == "ok"]
    out = {
        "n_cells": len(rows),
        "n_ok": len(ok),
        "rows": rows,
        "claims": {
            "all_baselines_present": len(rows) >= 30,
            "no_errors": all(r["status"] != "error" for r in rows),
        },
    }
    print("| arch | shape | compute_s | memory_s | collective_s | "
          "dominant | roofline_frac | useful_ratio |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(fmt_row(r))
    save("roofline", out)
    return out
