"""Roofline table generator: reads artifacts/dryrun/*.json (produced by
``python -m repro.launch.dryrun --all``) and emits the per-(arch x shape
x mesh) three-term roofline, dominant bottleneck, and useful-flops ratio
— the source of EXPERIMENTS.md §Roofline.

Also emits LSM-kernel rows: the engine's merge and fused-probe ops are
pure data movement (no flops to speak of), so their ceiling is the
MEASURED memory bandwidth times bytes moved.  Each row reports measured
time, the bytes-moved ceiling, and time-as-fraction-of-roofline — the
denominator ``kernels_bench`` speedups should be read against."""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from .common import save

DRYRUN = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


# ----------------------------------------------------- LSM kernel rows
def _memcpy_gbps(nbytes: int = 1 << 26, reps: int = 3) -> float:
    """Measured host memory bandwidth (GB/s) via a large ``np.copyto``
    (counts read+write traffic, the same convention as the rows)."""
    src = np.ones(nbytes // 8, np.float64)
    dst = np.empty_like(src)
    np.copyto(dst, src)                     # page in
    t0 = time.perf_counter()
    for _ in range(reps):
        np.copyto(dst, src)
    dt = (time.perf_counter() - t0) / reps
    return 2 * nbytes / dt / 1e9


def lsm_rows(quick: bool = False) -> list[dict]:
    """Bytes-moved roofline rows for the engine's merge / probe ops (the
    execution backend's host fast path — on CPU XLA that is also the
    dispatch winner, so these rows bound the serving data plane)."""
    from repro.core.backend import merge_kway_host
    from repro.kernels.bloom.ops import (bloom_build, bloom_probe_multi_host,
                                         filter_params, stack_filters)
    import jax.numpy as jnp

    rng = np.random.default_rng(3)
    bw = _memcpy_gbps(1 << 24 if quick else 1 << 26)
    rows = []

    # k-way merge: read k runs (8 B/entry), write the merged run
    n = 1 << 14 if quick else 1 << 17
    k = 4
    runs = []
    for _ in range(k):
        keys = np.unique(rng.integers(0, 8 * n, n, dtype=np.uint32))
        vals = rng.integers(0, 1 << 30, len(keys)).astype(np.int32)
        runs.append((keys, vals))
    n_in = sum(len(r[0]) for r in runs)
    merge_kway_host(runs)                   # warm
    t0 = time.perf_counter()
    mk, mv = merge_kway_host(runs)
    merge_ms = (time.perf_counter() - t0) * 1e3
    bytes_moved = 8 * (n_in + len(mk))      # 4 B key + 4 B val, in + out
    ceil_ms = bytes_moved / (bw * 1e9) * 1e3
    rows.append({"arch": "lsm_merge_kway", "shape": f"k{k}_n{n_in}",
                 "status": "ok", "mode": "host", "ms": merge_ms,
                 "bytes_moved": bytes_moved, "memcpy_gbps": bw,
                 "ceiling_ms": ceil_ms,
                 "frac_of_roofline": ceil_ms / merge_ms if merge_ms else 0.0})

    # fused multi-table probe: read q keys + k_hashes words per (table,
    # key) pair + the touched filter words, write the (t, q) mask
    t, q = 16, (1 << 12 if quick else 1 << 15)
    filts, nbl, khl = [], [], []
    for _ in range(t):
        keys = rng.integers(0, 1 << 24, 2048, dtype=np.uint32)
        n_bits, k_hashes = filter_params(len(keys), 0.01)
        filts.append(np.asarray(bloom_build(jnp.asarray(keys), n_bits,
                                            k_hashes)))
        nbl.append(n_bits)
        khl.append(k_hashes)
    stk, meta = stack_filters(filts, nbl, khl)
    qk = rng.integers(0, 1 << 24, q, dtype=np.uint32)
    bloom_probe_multi_host(stk, meta, qk)   # warm
    t0 = time.perf_counter()
    out = bloom_probe_multi_host(stk, meta, qk)
    probe_ms = (time.perf_counter() - t0) * 1e3
    k_avg = float(meta[:, 1].mean())
    bytes_moved = int(4 * q + 4 * k_avg * t * q + out.size)
    ceil_ms = bytes_moved / (bw * 1e9) * 1e3
    rows.append({"arch": "lsm_probe_multi", "shape": f"t{t}_q{q}",
                 "status": "ok", "mode": "host", "ms": probe_ms,
                 "bytes_moved": bytes_moved, "memcpy_gbps": bw,
                 "ceiling_ms": ceil_ms,
                 "frac_of_roofline": ceil_ms / probe_ms if probe_ms
                 else 0.0})
    return rows


def load_cells(mesh: str | None = None) -> list[dict]:
    cells = []
    for p in sorted(DRYRUN.glob("*.json")):
        d = json.loads(p.read_text())
        if mesh and d.get("mesh") != mesh:
            continue
        cells.append(d)
    return cells


def table(mesh: str = "single") -> list[dict]:
    rows = []
    for d in load_cells(mesh):
        if d.get("status") == "skipped":
            rows.append({"arch": d["arch"], "shape": d["shape"],
                         "status": "skipped",
                         "why": d.get("skip_reason", "")[:60]})
            continue
        if d.get("status") != "ok":
            rows.append({"arch": d["arch"], "shape": d["shape"],
                         "status": "error"})
            continue
        r = d["roofline"]
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "status": "ok",
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "dominant": r["dominant"],
            "roofline_frac": r["compute_s"] / bound if bound else 0.0,
            "useful_flops_ratio": d.get("useful_flops_ratio"),
            "model_flops": d.get("model_flops_6nd"),
        })
    return rows


def fmt_row(r: dict) -> str:
    if r["status"] != "ok":
        return (f"| {r['arch']} | {r['shape']} | {r['status']} "
                f"| - | - | - | - | - |")
    u = r["useful_flops_ratio"]
    return (f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} "
            f"| {r['memory_s']:.3g} | {r['collective_s']:.3g} "
            f"| {r['dominant']} | {r['roofline_frac']:.2f} "
            f"| {u:.2f} |" if u else "| ? |")


def run(quick: bool = False) -> dict:
    rows = table("single")
    ok = [r for r in rows if r["status"] == "ok"]
    lsm = lsm_rows(quick)
    out = {
        "n_cells": len(rows),
        "n_ok": len(ok),
        "rows": rows,
        "lsm_rows": lsm,
        "claims": {
            "all_baselines_present": len(rows) >= 30,
            "no_errors": all(r["status"] != "error" for r in rows),
            "lsm_rows_present": len(lsm) >= 2,
            # a bytes-moved ceiling bounds from BELOW: measured time can
            # only be slower (frac <= ~1; small slack for timer noise)
            "lsm_under_roofline": all(
                0.0 < r["frac_of_roofline"] <= 1.2 for r in lsm),
        },
    }
    print("| arch | shape | compute_s | memory_s | collective_s | "
          "dominant | roofline_frac | useful_ratio |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(fmt_row(r))
    print("| lsm op | shape | measured | ceiling | frac of roofline |")
    for r in lsm:
        print(f"| {r['arch']} | {r['shape']} | {r['ms']:.3g} ms "
              f"| {r['ceiling_ms']:.3g} ms "
              f"| {r['frac_of_roofline']:.2f} |")
    save("roofline", out)
    return out
