"""Figure 6: bLSM's spring-and-gear bounds processing latency, but write
latency (queuing included) explodes at 95% utilization."""
from __future__ import annotations

import numpy as np

from repro.core.blsm import BLSMSimulator
from repro.core.sim import ClosedClient, ConstantArrival, OpenClient

from .common import BANDWIDTH, UNIQUE, durations, save


def run(quick: bool = False) -> dict:
    test_s, run_s, warm = durations(quick)
    mk = lambda: BLSMSimulator(bandwidth=BANDWIDTH,
                               memory_entries=UNIQUE / 100.0,
                               size_ratio=10, unique_keys=UNIQUE)
    # testing phase (closed)
    sim = mk()
    tr = sim.run(ClosedClient(), test_s)
    max_tp = tr.throughput(t_from=warm)
    t, w = tr.windowed_throughput(30.0)
    w_late = w[t > warm]
    peak_ratio = float(np.max(w_late) / max(np.mean(w_late), 1e-9))
    # running phase (open, 95%)
    sim2 = mk()
    tr2 = sim2.run(OpenClient(ConstantArrival(0.95 * max_tp)), run_s)
    wl = tr2.write_latency_percentiles((50, 99))
    pl = tr2.processing_latency_percentiles((50, 99))
    result = {
        "max_throughput": max_tp,
        "testing_peak_over_mean": peak_ratio,
        "write_p99_s": wl[99],
        "processing_p99_s": pl[99],
        "claims": {
            # Fig 6a: periodic peaks right after C1 swaps
            "testing_throughput_has_peaks": peak_ratio > 1.3,
            # Fig 6c: processing latency bounded, write latency >> it
            "write_latency_much_larger_than_processing":
                wl[99] > 10 * max(pl[99], 1e-9),
        },
    }
    save("fig06_blsm", result)
    return result
