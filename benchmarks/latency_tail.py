"""Writer/reader tail latency under a LIVE background plane, and
read-view maintenance cost vs table count — the bounded-latency claims
of the streaming background plane (PR 5).

Section A (tail): a wall-clock ``BackgroundDriver`` pumps a merge-heavy
tiering engine (three preloaded tables at each of three levels, so the
run cascades L0 -> L1 -> L2 merges up to ~10M entries) while an OPEN-LOOP
foreground issues ``put_batch`` writes and ``scan_range`` reads at fixed
scheduled arrival times; each op's latency is completion - SCHEDULED
time, so a lock-hold stall charges every op it delays (no coordinated
omission).  Compared before/after: ``streaming_merge=False`` is the
one-shot baseline whose first merge quantum materializes the ENTIRE
merged run under the engine lock; streaming merges bound every quantum's
work by the quantum.  The acceptance bar is a >= 5x writer p99
improvement (>= 1.5x in --quick, where merges are small enough that
fixed overheads dominate).

Section B (view maintenance): per-background-event read-view upkeep at
N live tables.  Old path (the seed, measured verbatim): full
``(-stamp, level)`` re-sort + ``stack_filters`` restack + device upload
of every live filter — O(tables * filter-bytes) per event.  New path:
the O(tables) ``_read_view`` snapshot plus the persistent filter
stack's one-row reconcile.  Bar: >= 10x cheaper at >= 64 tables
(>= 1.5x sanity bar in --quick, whose small stacks sit on the one-row
write's dispatch floor).

    PYTHONPATH=src python -m benchmarks.latency_tail [--quick]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.engine import BackgroundDriver, LSMEngine
from repro.core.metrics import LatencyRecorder
from repro.core.policies import TieringPolicy
from repro.core.scheduler import FairScheduler
from repro.core.sstable import SSTable

from .common import save
from .engine_throughput import _FlushOnlyPolicy

KEY_SPACE = 1 << 22
MEMTABLE = 16_384


# ------------------------------------------------------------------ helpers
def _inject_table(eng: LSMEngine, rng, n: int, level: int) -> None:
    """Register a prebuilt sorted run with flush-identical semantics."""
    keys = np.unique(rng.integers(0, KEY_SPACE, int(n * 1.3),
                                  dtype=np.uint32))[:n]
    vals = rng.integers(0, 1 << 30, len(keys)).astype(np.int32)
    table = SSTable.build(keys, vals, level=level, created_at=eng.now,
                          interpret=eng.interpret)
    eng._bind_table(table)


def _mk_tail_engine(streaming: bool, level_sizes: list[int]) -> LSMEngine:
    """Tiering engine preloaded with 3 tables per level: the first flush
    tips L0 to T=4 and the merge outputs cascade level by level."""
    eng = LSMEngine(TieringPolicy(4, MEMTABLE, KEY_SPACE), FairScheduler(),
                    None, memtable_entries=MEMTABLE, num_memtables=4,
                    unique_keys=KEY_SPACE, use_kernels=False,
                    streaming_merge=streaming)
    rng = np.random.default_rng(42)
    for level, n in enumerate(level_sizes):
        for _ in range(3):
            _inject_table(eng, rng, n, level)
    return eng


def _run_tail(streaming: bool, duration: float, level_sizes: list[int],
              bw_bytes: float, rate_ops: float, batch: int,
              read_every: int) -> dict:
    eng = _mk_tail_engine(streaming, level_sizes)
    drv = BackgroundDriver(eng, bw_bytes, quantum_s=0.005)
    wrec, rrec = LatencyRecorder(), LatencyRecorder()
    rng = np.random.default_rng(7)
    lock = eng.lock()
    interval = 1.0 / rate_ops
    drv.start()
    try:
        t0 = time.monotonic()
        i = 0
        while True:
            sched = t0 + i * interval
            lag = sched - time.monotonic()
            if lag > 0:
                time.sleep(lag)
            if time.monotonic() - t0 >= duration:
                break
            if read_every and i % read_every == read_every - 1:
                lo = int(rng.integers(0, KEY_SPACE - 4096))
                with lock:
                    eng.scan_range(lo, lo + 4096)
                rrec.observe(time.monotonic() - sched)
            else:
                keys = rng.integers(0, KEY_SPACE, batch, dtype=np.uint32)
                vals = rng.integers(0, 1 << 30, batch, dtype=np.int32)
                # retry until the WHOLE batch is admitted: a stalled
                # engine rejecting in microseconds must not be recorded
                # as a completed near-zero-latency write — the op
                # completes when its last entry lands
                done = 0
                while done < batch:
                    with lock:
                        took = eng.put_batch(keys[done:], vals[done:])
                    done += took
                    if took == 0:
                        time.sleep(2e-4)     # let the driver drain
                wrec.observe(time.monotonic() - sched)
            i += 1
    finally:
        drv.stop()
    return {"streaming": streaming,
            "writer": wrec.summary(), "reader": rrec.summary(),
            "merges": eng.stats["merges"],
            "merge_touched": eng.stats["merge_touched"],
            "flushes": eng.stats["flushes"],
            "live_tables": len(eng.tables)}


# --------------------------------------------------- view maintenance cost
def _seed_view_maintenance(eng: LSMEngine):
    """The pre-PR read-view build, verbatim: full re-sort of the live
    tables + ``stack_filters`` restack + device upload of every filter
    — the O(tables * filter-bytes) per-event cost this PR retires."""
    import jax.numpy as jnp
    from repro.kernels.bloom.ops import stack_filters
    tables = tuple(sorted(
        (t for t in eng.tables.values() if t.component is not None),
        key=lambda t: (-t.data_stamp, t.component.level)))
    filts, meta = stack_filters([t.bloom_host() for t in tables],
                                [t.n_bits for t in tables],
                                [t.k_hashes for t in tables])
    return jnp.asarray(filts).block_until_ready(), meta, tables


def _bench_view(tables: int, entries: int, reps: int) -> dict:
    eng = LSMEngine(_FlushOnlyPolicy(1 << 20, entries, KEY_SPACE),
                    FairScheduler(), None, memtable_entries=entries,
                    num_memtables=2, unique_keys=KEY_SPACE)
    rng = np.random.default_rng(tables)

    def flush_one():
        keys = rng.choice(KEY_SPACE, entries, replace=False).astype(
            np.uint32)
        vals = rng.integers(0, 1 << 30, entries).astype(np.int32)
        assert eng.put_batch(keys, vals) == entries
        eng._seal_active()
        eng.pump(entries)

    for _ in range(tables):
        flush_one()
    # warm: builds every filter + the stack + the probe's jit paths
    eng.get_batch(rng.integers(0, KEY_SPACE, 64, dtype=np.uint32))

    new_s, old_s = [], []
    for _ in range(reps):
        flush_one()
        # charge neither path for the new table's one-time filter build
        # (the old path paid it at flush, the new one at first read)
        eng._order[0].bloom_host()
        t0 = time.perf_counter()
        view = eng._read_view()
        filts, _ = eng._view_filters(view)
        filts.block_until_ready()
        new_s.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        _seed_view_maintenance(eng)
        old_s.append(time.perf_counter() - t0)

    new_t, old_t = min(new_s), min(old_s)
    return {"tables": tables + reps, "entries_per_table": entries,
            "incremental_s": new_t, "full_restack_s": old_t,
            "speedup": old_t / new_t}


def run(quick: bool = False) -> dict:
    if quick:
        level_sizes = [49_152, 147_456]
        duration, bw = 3.0, 2.5e8
        writer_bar, view_bar, view_claim_tables = 1.5, 1.5, 0
        view_grid = [(16, 16_384)]
        reps = 6
    else:
        level_sizes = [196_608, 786_432, 2_359_296]
        duration, bw = 10.0, 4.0e8
        writer_bar, view_bar, view_claim_tables = 5.0, 10.0, 64
        view_grid = [(16, 16_384), (96, 131_072)]
        reps = 8

    oneshot = _run_tail(False, duration, level_sizes, bw,
                        rate_ops=400.0, batch=128, read_every=8)
    streaming = _run_tail(True, duration, level_sizes, bw,
                          rate_ops=400.0, batch=128, read_every=8)
    w_ratio = oneshot["writer"]["p99"] / max(streaming["writer"]["p99"],
                                             1e-9)
    r_ratio = oneshot["reader"]["p99"] / max(streaming["reader"]["p99"],
                                             1e-9)

    views = [_bench_view(t, e, reps) for (t, e) in view_grid]

    out = {"tail": {"oneshot": oneshot, "streaming": streaming,
                    "writer_p99_ratio": w_ratio,
                    "reader_p99_ratio": r_ratio},
           "view_maintenance": views,
           "writer_bar": writer_bar, "view_bar": view_bar,
           "claims": {}}
    out["claims"]["writer_p99_bar_met"] = w_ratio >= writer_bar
    out["claims"]["streaming_merges_ran"] = streaming["merges"] >= 2 and \
        oneshot["merges"] >= 2
    # the maintenance bar applies at scale (>= 64 live tables in the
    # full run); smaller rows are informational — the dispatch floor of
    # one device row write dominates tiny stacks
    gated = [v for v in views if v["tables"] >= view_claim_tables]
    out["claims"]["view_maintenance_bar_met"] = bool(gated) and all(
        v["speedup"] >= view_bar for v in gated)
    save("latency_tail", out)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    res = run(quick=ap.parse_args().quick)
    for mode in ("oneshot", "streaming"):
        t = res["tail"][mode]
        print(f"[tail] {mode:9s} writer p50/p99/p999 = "
              f"{t['writer']['p50']*1e3:7.2f}/{t['writer']['p99']*1e3:8.2f}/"
              f"{t['writer']['p999']*1e3:8.2f} ms   reader p99 = "
              f"{t['reader']['p99']*1e3:8.2f} ms   "
              f"({t['merges']} merges, {t['flushes']} flushes)")
    print(f"[tail] writer p99 improvement: "
          f"{res['tail']['writer_p99_ratio']:.1f}x   reader p99: "
          f"{res['tail']['reader_p99_ratio']:.1f}x")
    for v in res["view_maintenance"]:
        print(f"[view] {v['tables']:3d} tables x {v['entries_per_table']}: "
              f"incremental {v['incremental_s']*1e6:8.1f} us   "
              f"full restack {v['full_restack_s']*1e6:8.1f} us   "
              f"speedup {v['speedup']:.1f}x")
    print(json.dumps(res["claims"], indent=1))
    raise SystemExit(0 if all(res["claims"].values()) else 1)
