"""Shared helpers for the paper-figure benchmarks.

Every module exposes ``run(quick=False) -> dict`` with a ``claims`` map
of named boolean validations against the paper's qualitative results.
``quick`` shortens simulated durations ~10x for CI; the full settings
match the paper (2 h phases, 100 MB/s budget, 128 MB memtables, 100 M
unique 1 KB records scaled down 10x to keep DES event counts tractable —
ratios, not absolutes, carry the claims).
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.core.constraints import (GlobalConstraint, L0Constraint,
                                    LocalConstraint)
from repro.core.policies import (LevelingPolicy, PartitionedLevelingPolicy,
                                 SizeTieredPolicy, TieringPolicy)
from repro.core.scheduler import (FairScheduler, GreedyScheduler,
                                  SingleThreadedScheduler)
from repro.core.sim import LSMSimulator, SimConfig
from repro.core.twophase import run_two_phase

ART = Path(__file__).resolve().parent.parent / "artifacts" / "bench"

# paper scale / 10 (events, not ratios): 10M uniques, 12.8MB memtable,
# 10 MB/s budget => identical level counts and utilization structure.
UNIQUE = 10e6
MEMTABLE = 13_107.2
BANDWIDTH = 10_240.0


def sim_config() -> SimConfig:
    return SimConfig(bandwidth=BANDWIDTH, memtable_entries=MEMTABLE,
                     unique_keys=UNIQUE, mem_write_rate=250_000.0)


def durations(quick: bool) -> tuple[float, float, float]:
    """(testing_s, running_s, warmup_s).  Even "quick" must cover several
    largest-level merges (~1000 s each at this scale) or leveling's
    dynamics are invisible; the DES makes either cheap."""
    return (3600.0, 3600.0, 600.0) if quick else (7200.0, 7200.0, 1200.0)


def make_system(policy_name: str, scheduler_name: str,
                constraint: str = "global", size_ratio: int | None = None,
                **pol_kw):
    def factory():
        T = size_ratio
        if policy_name == "tiering":
            pol = TieringPolicy(T or 3, MEMTABLE, UNIQUE)
        elif policy_name == "leveling":
            pol = LevelingPolicy(T or 10, MEMTABLE, UNIQUE, **pol_kw)
        elif policy_name == "size_tiered":
            pol = SizeTieredPolicy(T or 1.2, MEMTABLE, UNIQUE, **pol_kw)
        elif policy_name == "partitioned":
            pol = PartitionedLevelingPolicy(T or 10, MEMTABLE, UNIQUE,
                                            **pol_kw)
        else:
            raise ValueError(policy_name)
        sched = {"single": SingleThreadedScheduler, "fair": FairScheduler,
                 "greedy": GreedyScheduler}[scheduler_name]()
        if constraint == "global":
            cons = GlobalConstraint(2 * pol.expected_components())
        elif constraint == "local":
            per = 2 if policy_name == "leveling" else 2 * (T or 3)
            cons = LocalConstraint(per)
        elif constraint == "fifty":          # paper's size-tiered setup
            cons = GlobalConstraint(50)
        elif constraint == "l0":             # LevelDB stop threshold
            cons = L0Constraint(12)
        else:
            cons = None
        return LSMSimulator(pol, sched, cons, sim_config())
    return factory


def save(name: str, result: dict):
    ART.mkdir(parents=True, exist_ok=True)
    (ART / f"{name}.json").write_text(json.dumps(result, indent=1,
                                                 default=float))


def pct_ok(result) -> dict:
    return {str(k): float(v) for k, v in result.items()}
