"""Benchmark orchestrator:
``python -m benchmarks.run [--quick|--smoke] [--only m]``.

Runs every paper-figure benchmark + the framework-integration ones,
prints each module's claims map, and exits nonzero if any claim fails.
Results land in artifacts/bench/*.json.

``--smoke`` is the CI rot check: every module runs at its quick sizes,
claims are still reported, but only module ERRORS fail the run —
performance bars are meaningless at smoke sizes; the point is that
benchmark code keeps importing and executing between perf PRs.
"""
from __future__ import annotations

import argparse
import importlib
import time
import traceback

MODULES = [
    "fig01_stalls",
    "fig06_blsm",
    "fig08_testing",
    "fig09_10_running",
    "fig11_size_ratio",
    "fig12_constraints",
    "fig13_bursts",
    "fig14_17_queries",
    "fig19_20_sizetiered",
    "fig21_23_partitioned",
    "fig24_partition_size",
    "fig25_27_secondary",
    "engine_throughput",
    "twophase_engine",
    "secondary_engine",
    "latency_tail",
    "kernels_bench",
    "ckpt_twophase",
    "serving_twophase",
    "fleet_scaling",
    "roofline",
    "recovery",
    "availability",
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-size rot check: quick sizes, only module "
                         "errors fail (claims reported, not gated)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    mods = [args.only] if args.only else MODULES
    n_claims = n_pass = n_err = 0
    for name in mods:
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            res = mod.run(quick=args.quick or args.smoke)
            claims = res.get("claims", {})
            ok = sum(bool(v) for v in claims.values())
            n_claims += len(claims)
            n_pass += ok
            status = "PASS" if ok == len(claims) else "PARTIAL"
            print(f"[bench] {name:24s} {status} ({ok}/{len(claims)} claims, "
                  f"{time.time() - t0:.1f}s)")
            for k, v in claims.items():
                if not v:
                    print(f"    FAILED CLAIM: {k}")
        except Exception as e:
            n_err += 1
            print(f"[bench] {name:24s} ERROR: {e!r}")
            traceback.print_exc()
    print(f"[bench] total: {n_pass}/{n_claims} claims pass, "
          f"{n_err} module errors")
    if args.smoke:
        return 0 if n_err == 0 else 1
    return 0 if (n_pass == n_claims and n_err == 0) else 1


if __name__ == "__main__":
    raise SystemExit(main())
