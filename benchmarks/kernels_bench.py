"""Per-kernel microbenchmarks (interpret mode on CPU — wall numbers are
for regression tracking, not TPU projections) + oracle agreement."""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from .common import save


def _time(fn, *args, reps=3):
    fn(*args)                      # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e3   # ms


def run(quick: bool = False) -> dict:
    out: dict = {"claims": {}}
    rng = np.random.default_rng(0)

    # merge kernel
    from repro.kernels.merge.ops import merge_dedup
    from repro.kernels.merge.ref import merge_dedup_ref
    n = 4096 if quick else 16384
    ka = np.sort(rng.choice(1 << 20, n, replace=False)).astype(np.uint32)
    kb = np.sort(rng.choice(1 << 20, n, replace=False)).astype(np.uint32)
    va = rng.integers(0, 1 << 30, n).astype(np.int32)
    vb = rng.integers(0, 1 << 30, n).astype(np.int32)
    mk, mv, keep, valid = merge_dedup(jnp.asarray(ka), jnp.asarray(va),
                                      jnp.asarray(kb), jnp.asarray(vb),
                                      block=256)
    keep = np.array(keep)
    keep[valid:] = False
    rk, rv = merge_dedup_ref(ka, va, kb, vb)
    agree = np.array_equal(np.asarray(mk)[keep], rk) and \
        np.array_equal(np.asarray(mv)[keep], rv)
    out["merge"] = {
        "n": n,
        "ms": _time(lambda: merge_dedup(jnp.asarray(ka), jnp.asarray(va),
                                        jnp.asarray(kb), jnp.asarray(vb),
                                        block=256)),
        "oracle_agree": bool(agree),
    }
    out["claims"]["merge_matches_oracle"] = bool(agree)

    # bloom kernel
    from repro.kernels.bloom.ops import bloom_build, bloom_probe, filter_params
    keys = rng.choice(1 << 24, n, replace=False).astype(np.uint32)
    n_bits, k_hashes = filter_params(n, 0.01)
    filt = bloom_build(jnp.asarray(keys), n_bits, k_hashes)
    present = bloom_probe(filt, jnp.asarray(keys), n_bits, k_hashes)
    absent_keys = rng.choice(1 << 24, 4 * n, replace=False).astype(np.uint32)
    absent_keys = np.setdiff1d(absent_keys, keys)[:n]
    fp = float(np.mean(np.asarray(
        bloom_probe(filt, jnp.asarray(absent_keys), n_bits, k_hashes))))
    out["bloom"] = {
        "n": n, "fp_rate": fp, "n_bits": n_bits, "k_hashes": k_hashes,
        "probe_ms": _time(lambda: bloom_probe(filt, jnp.asarray(keys),
                                              n_bits, k_hashes)),
        "no_false_negatives": bool(np.asarray(present).all()),
    }
    out["claims"]["bloom_no_false_negatives"] = bool(
        np.asarray(present).all())
    out["claims"]["bloom_fp_near_target"] = fp < 0.03

    # attention kernel
    from repro.kernels.attention.ops import attention
    from repro.kernels.attention.ref import attention_ref
    B, H, Hkv, S, D = 1, 4, 2, 256, 32
    q = jnp.asarray(rng.standard_normal((B, H, S, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
    o = attention(q, k, v, causal=True, bq=64, bk=64)
    ref = attention_ref(q, k, v, causal=True)
    err = float(jnp.max(jnp.abs(o - ref)))
    out["attention"] = {"max_err": err,
                        "ms": _time(lambda: attention(q, k, v, causal=True,
                                                      bq=64, bk=64))}
    out["claims"]["attention_matches_oracle"] = err < 2e-3

    # ssd kernel
    from repro.kernels.ssd.ops import ssd
    from repro.kernels.ssd.ref import ssd_scan_ref as ssd_ref
    BH, L, P, N = 2, 128, 16, 8
    x = jnp.asarray(rng.standard_normal((BH, L, P)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((BH, L, N)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((BH, L, N)), jnp.float32)
    alog = jnp.asarray(-np.abs(rng.standard_normal((BH, L))) * 0.1,
                       jnp.float32)
    dt = jnp.asarray(np.abs(rng.standard_normal((BH, L))) * 0.1, jnp.float32)
    y = ssd(x, b, c, alog, dt, chunk=32)
    yr = ssd_ref(x, b, c, alog, dt)
    err = float(jnp.max(jnp.abs(y - yr)))
    out["ssd"] = {"max_err": err,
                  "ms": _time(lambda: ssd(x, b, c, alog, dt, chunk=32))}
    out["claims"]["ssd_matches_oracle"] = err < 2e-3

    # paged decode attention (block-table indirection)
    from repro.kernels.paged_attention.paged_attention import \
        paged_attention_kernel
    from repro.kernels.paged_attention.ref import paged_attention_ref
    B, Hkv, G, D, page, n_pages, mp = 4, 2, 4, 32, 16, 64, 8
    q = jnp.asarray(rng.standard_normal((B, Hkv, G, D)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((n_pages, Hkv, page, D)),
                     jnp.float32)
    vp = jnp.asarray(rng.standard_normal((n_pages, Hkv, page, D)),
                     jnp.float32)
    tables = jnp.asarray(np.stack([
        rng.choice(n_pages, mp, replace=False) for _ in range(B)]),
        jnp.int32)
    lens = jnp.asarray(rng.integers(1, mp * page, B), jnp.int32)
    o = paged_attention_kernel(q, kp, vp, tables, lens)
    ref = paged_attention_ref(q, kp, vp, tables, lens)
    err = float(jnp.max(jnp.abs(o - ref)))
    out["paged_attention"] = {
        "max_err": err,
        "ms": _time(lambda: paged_attention_kernel(q, kp, vp, tables,
                                                   lens))}
    out["claims"]["paged_attention_matches_oracle"] = err < 2e-4

    save("kernels_bench", out)
    return out
