"""Per-kernel microbenchmarks (interpret mode on CPU — wall numbers are
for regression tracking, not TPU projections) + oracle agreement + the
execution-backend calibration sweep.

The sweep times every AVAILABLE execution mode (host numpy / interpret
Pallas / compiled Pallas when the XLA backend can lower it) for each
backend op at a grid of sizes, checks the modes agree bit-for-bit, and
persists the fastest-mode-per-(op, size) crossover table to
``artifacts/bench/backend_calibration.json`` — the table
``core.backend.ExecBackend`` loads at engine construction."""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from .common import save


def _time(fn, *args, reps=3):
    fn(*args)                      # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e3   # ms


# ------------------------------------------------- backend calibration
def _sweep_runs(rng, total: int, k: int):
    """k sorted-unique runs totaling ~``total`` entries (newest first)."""
    per = max(total // k, 1)
    runs = []
    for _ in range(k):
        keys = np.unique(rng.integers(0, 4 * per * k, per,
                                      dtype=np.uint32))
        vals = rng.integers(0, 1 << 30, len(keys)).astype(np.int32)
        runs.append((keys, vals))
    return runs


def calibration_sweep(quick: bool = False) -> dict:
    """Time host/interpret/compiled for every backend op at a size grid;
    returns ``{"table": <crossover table>, "agree": bool, "path": str}``
    after persisting the table to the calibration artifact."""
    from repro.core.backend import (compiled_supported, merge_kway_host,
                                    write_calibration)
    from repro.kernels.bloom.ops import (bloom_build, bloom_probe_multi,
                                         bloom_probe_multi_host,
                                         filter_params, stack_filters)
    from repro.kernels.merge.ops import merge_dedup_kway

    rng = np.random.default_rng(7)
    sizes = [512, 2048] if quick else [512, 4096, 16384]
    has_compiled = compiled_supported()
    modes = ["host", "interpret"] + (["compiled"] if has_compiled else [])
    table: dict = {"ops": {}}
    agree = True

    def record(op: str, timers: dict, checks: dict) -> None:
        nonlocal agree
        ms = {m: [] for m in modes}
        best = []
        for s in sizes:
            outs = {}
            for m in modes:
                ms[m].append(timers[m](s))
                outs[m] = checks[m](s)
            ref = outs["host"]
            for m in modes[1:]:
                same = all(np.array_equal(np.asarray(a), np.asarray(b))
                           for a, b in zip(ref, outs[m]))
                agree = agree and same
            best.append(min(modes, key=lambda m: ms[m][-1]))
        table["ops"][op] = {"sizes": sizes, "best": best, "ms": ms}

    # -- merges (merge_kway; merge_kway_window aliases to it) ----------
    run_cache: dict = {}

    def merge_runs(s, k):
        # operands are generated ONCE per (size, k) and reused by every
        # timed mode, so the sweep compares merge cost, not data gen
        if (s, k) not in run_cache:
            runs = _sweep_runs(np.random.default_rng(s), s, k)
            run_cache[(s, k)] = (runs,
                                 [(jnp.asarray(a), jnp.asarray(b))
                                  for a, b in runs])
        return run_cache[(s, k)]

    def m_host(s, k):
        return merge_kway_host(merge_runs(s, k)[0])

    def m_kern(s, interpret, k):
        mk, mv = merge_dedup_kway(merge_runs(s, k)[1], block=256,
                                  interpret=interpret)
        return np.asarray(mk), np.asarray(mv)

    for op, k in (("merge_kway", 4), ("scan_merge", 8)):
        record(op,
               timers={"host": lambda s, k=k: _time(m_host, s, k, reps=1),
                       "interpret": lambda s, k=k: _time(
                           m_kern, s, True, k, reps=1),
                       "compiled": lambda s, k=k: _time(
                           m_kern, s, False, k, reps=1)},
               checks={"host": lambda s, k=k: m_host(s, k),
                       "interpret": lambda s, k=k: m_kern(s, True, k),
                       "compiled": lambda s, k=k: m_kern(s, False, k)})

    # -- fused probe (size = tables * keys, 8 tables) ------------------
    def probe_operands(s, t=8):
        r = np.random.default_rng(s)
        filts, nb, kh = [], [], []
        for _ in range(t):
            keys = r.integers(0, 1 << 24, 512, dtype=np.uint32)
            n_bits, k_hashes = filter_params(len(keys), 0.01)
            filts.append(np.asarray(bloom_build(jnp.asarray(keys),
                                                n_bits, k_hashes)))
            nb.append(n_bits)
            kh.append(k_hashes)
        stk, meta = stack_filters(filts, nb, kh)
        q = r.integers(0, 1 << 24, max(s // t, 1), dtype=np.uint32)
        return stk, jnp.asarray(stk), meta, q

    def p_host(ops):
        return (bloom_probe_multi_host(ops[0], ops[2], ops[3]),)

    def p_kern(ops, interpret):
        return (np.asarray(bloom_probe_multi(ops[1], ops[2], ops[3],
                                             interpret=interpret)),)

    cache: dict = {}

    def probe_ops(s):
        if s not in cache:
            cache[s] = probe_operands(s)
        return cache[s]

    record("probe_multi",
           timers={"host": lambda s: _time(
                       lambda: p_host(probe_ops(s)), reps=1),
                   "interpret": lambda s: _time(
                       lambda: p_kern(probe_ops(s), True), reps=1),
                   "compiled": lambda s: _time(
                       lambda: p_kern(probe_ops(s), False), reps=1)},
           checks={"host": lambda s: p_host(probe_ops(s)),
                   "interpret": lambda s: p_kern(probe_ops(s), True),
                   "compiled": lambda s: p_kern(probe_ops(s), False)})

    table["compiled_supported"] = has_compiled
    table["quick"] = bool(quick)
    path = write_calibration(table)
    return {"table": table, "agree": bool(agree), "path": str(path)}


def run(quick: bool = False) -> dict:
    out: dict = {"claims": {}}
    rng = np.random.default_rng(0)

    # merge kernel
    from repro.kernels.merge.ops import merge_dedup
    from repro.kernels.merge.ref import merge_dedup_ref
    n = 4096 if quick else 16384
    ka = np.sort(rng.choice(1 << 20, n, replace=False)).astype(np.uint32)
    kb = np.sort(rng.choice(1 << 20, n, replace=False)).astype(np.uint32)
    va = rng.integers(0, 1 << 30, n).astype(np.int32)
    vb = rng.integers(0, 1 << 30, n).astype(np.int32)
    mk, mv, keep, valid = merge_dedup(jnp.asarray(ka), jnp.asarray(va),
                                      jnp.asarray(kb), jnp.asarray(vb),
                                      block=256)
    keep = np.array(keep)
    keep[valid:] = False
    rk, rv = merge_dedup_ref(ka, va, kb, vb)
    agree = np.array_equal(np.asarray(mk)[keep], rk) and \
        np.array_equal(np.asarray(mv)[keep], rv)
    out["merge"] = {
        "n": n,
        "ms": _time(lambda: merge_dedup(jnp.asarray(ka), jnp.asarray(va),
                                        jnp.asarray(kb), jnp.asarray(vb),
                                        block=256)),
        "oracle_agree": bool(agree),
    }
    out["claims"]["merge_matches_oracle"] = bool(agree)

    # bloom kernel
    from repro.kernels.bloom.ops import bloom_build, bloom_probe, filter_params
    keys = rng.choice(1 << 24, n, replace=False).astype(np.uint32)
    n_bits, k_hashes = filter_params(n, 0.01)
    filt = bloom_build(jnp.asarray(keys), n_bits, k_hashes)
    present = bloom_probe(filt, jnp.asarray(keys), n_bits, k_hashes)
    absent_keys = rng.choice(1 << 24, 4 * n, replace=False).astype(np.uint32)
    absent_keys = np.setdiff1d(absent_keys, keys)[:n]
    fp = float(np.mean(np.asarray(
        bloom_probe(filt, jnp.asarray(absent_keys), n_bits, k_hashes))))
    out["bloom"] = {
        "n": n, "fp_rate": fp, "n_bits": n_bits, "k_hashes": k_hashes,
        "probe_ms": _time(lambda: bloom_probe(filt, jnp.asarray(keys),
                                              n_bits, k_hashes)),
        "no_false_negatives": bool(np.asarray(present).all()),
    }
    out["claims"]["bloom_no_false_negatives"] = bool(
        np.asarray(present).all())
    out["claims"]["bloom_fp_near_target"] = fp < 0.03

    # attention kernel
    from repro.kernels.attention.ops import attention
    from repro.kernels.attention.ref import attention_ref
    B, H, Hkv, S, D = 1, 4, 2, 256, 32
    q = jnp.asarray(rng.standard_normal((B, H, S, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
    o = attention(q, k, v, causal=True, bq=64, bk=64)
    ref = attention_ref(q, k, v, causal=True)
    err = float(jnp.max(jnp.abs(o - ref)))
    out["attention"] = {"max_err": err,
                        "ms": _time(lambda: attention(q, k, v, causal=True,
                                                      bq=64, bk=64))}
    out["claims"]["attention_matches_oracle"] = err < 2e-3

    # ssd kernel
    from repro.kernels.ssd.ops import ssd
    from repro.kernels.ssd.ref import ssd_scan_ref as ssd_ref
    BH, L, P, N = 2, 128, 16, 8
    x = jnp.asarray(rng.standard_normal((BH, L, P)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((BH, L, N)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((BH, L, N)), jnp.float32)
    alog = jnp.asarray(-np.abs(rng.standard_normal((BH, L))) * 0.1,
                       jnp.float32)
    dt = jnp.asarray(np.abs(rng.standard_normal((BH, L))) * 0.1, jnp.float32)
    y = ssd(x, b, c, alog, dt, chunk=32)
    yr = ssd_ref(x, b, c, alog, dt)
    err = float(jnp.max(jnp.abs(y - yr)))
    out["ssd"] = {"max_err": err,
                  "ms": _time(lambda: ssd(x, b, c, alog, dt, chunk=32))}
    out["claims"]["ssd_matches_oracle"] = err < 2e-3

    # paged decode attention (block-table indirection)
    from repro.kernels.paged_attention.paged_attention import \
        paged_attention_kernel
    from repro.kernels.paged_attention.ref import paged_attention_ref
    B, Hkv, G, D, page, n_pages, mp = 4, 2, 4, 32, 16, 64, 8
    q = jnp.asarray(rng.standard_normal((B, Hkv, G, D)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((n_pages, Hkv, page, D)),
                     jnp.float32)
    vp = jnp.asarray(rng.standard_normal((n_pages, Hkv, page, D)),
                     jnp.float32)
    tables = jnp.asarray(np.stack([
        rng.choice(n_pages, mp, replace=False) for _ in range(B)]),
        jnp.int32)
    lens = jnp.asarray(rng.integers(1, mp * page, B), jnp.int32)
    o = paged_attention_kernel(q, kp, vp, tables, lens)
    ref = paged_attention_ref(q, kp, vp, tables, lens)
    err = float(jnp.max(jnp.abs(o - ref)))
    out["paged_attention"] = {
        "max_err": err,
        "ms": _time(lambda: paged_attention_kernel(q, kp, vp, tables,
                                                   lens))}
    out["claims"]["paged_attention_matches_oracle"] = err < 2e-4

    # backend calibration sweep: time every available execution mode per
    # op per size, pin cross-mode agreement, persist the crossover table
    from pathlib import Path
    cal = calibration_sweep(quick=quick)
    out["backend_calibration"] = {
        "path": cal["path"],
        "compiled_supported": cal["table"]["compiled_supported"],
        "best": {op: t["best"] for op, t in cal["table"]["ops"].items()},
    }
    out["claims"]["backend_modes_agree"] = cal["agree"]
    out["claims"]["calibration_artifact_written"] = \
        Path(cal["path"]).exists()

    save("kernels_bench", out)
    return out
