"""Figures 25-27: secondary indexes.  Lazy maintenance behaves like the
single-tree case; eager maintenance is bottlenecked by point lookups
whose throughput varies with the number of disk components, forcing
utilization down to ~80% for low tail latency.

Model: primary + 2 secondary LSM-trees share the I/O budget (lazy =
1/3 bandwidth per tree, no lookups).  Eager adds a write-rate controller
``cap(t) = C / (a + b * n_components(t))`` — the paper's mechanism that
lookup cost scales with live component count.
"""
from __future__ import annotations

import numpy as np

from repro.core.constraints import GlobalConstraint
from repro.core.policies import TieringPolicy
from repro.core.scheduler import FairScheduler, GreedyScheduler
from repro.core.sim import (ClosedClient, ConstantArrival, LSMSimulator,
                            OpenClient, SimConfig)

from .common import BANDWIDTH, MEMTABLE, UNIQUE, durations, save


def _sim(scheduler, controller=None):
    pol = TieringPolicy(3, MEMTABLE, UNIQUE)
    cons = GlobalConstraint(2 * pol.expected_components())
    cfg = SimConfig(bandwidth=BANDWIDTH / 3.0, memtable_entries=MEMTABLE,
                    unique_keys=UNIQUE, mem_write_rate=250_000.0)
    return LSMSimulator(pol, scheduler, cons, cfg,
                        write_controller=controller)


def _eager_controller(base_rate: float):
    # lookup-bound ingestion: throughput ~ C / (1 + b*n + c*[merging]) —
    # lookups slow with component count AND with ongoing disk activity
    # (the paper's stated variance sources).  b/c calibrated so eager max
    # ~= 0.7x lazy (paper: 0.78x) and p99 is small only below ~80% util.
    def ctrl(t, tree):
        n = tree.num_components()
        merging = any(x.merging for x in tree.all_components())
        return base_rate / (1.0 + 0.06 * n + 0.5 * merging)
    return ctrl


def run(quick: bool = False) -> dict:
    test_s, run_s, warm = durations(quick)
    out: dict = {"claims": {}}

    # testing phase for both strategies (fair scheduler)
    lazy_t = _sim(FairScheduler()).run(ClosedClient(), test_s)
    lazy_max = lazy_t.throughput(t_from=warm)
    eager_sim = _sim(FairScheduler(),
                     controller=_eager_controller(lazy_max * 1.3))
    eager_t = eager_sim.run(ClosedClient(), test_s)
    eager_max = eager_t.throughput(t_from=warm)
    out["lazy_max"] = lazy_max
    out["eager_max"] = eager_max

    # running phase at 95% for each strategy x scheduler
    for name, mk in (("lazy", lambda s: _sim(s)),
                     ("eager", lambda s: _sim(
                         s, controller=_eager_controller(lazy_max * 1.3)))):
        mx = lazy_max if name == "lazy" else eager_max
        for sched_name, sched in (("fair", FairScheduler()),
                                  ("greedy", GreedyScheduler())):
            sim = mk(sched)
            tr = sim.run(OpenClient(ConstantArrival(0.95 * mx)), run_s)
            out[f"{name}_{sched_name}_p99"] = \
                tr.write_latency_percentiles((99,))[99]

    # Figure 27: eager p99 vs utilization sweep
    utils = [0.6, 0.8, 0.95] if quick else [0.5, 0.6, 0.7, 0.8, 0.9, 0.95]
    sweep = []
    for u in utils:
        sim = _sim(GreedyScheduler(),
                   controller=_eager_controller(lazy_max * 1.3))
        tr = sim.run(OpenClient(ConstantArrival(u * eager_max)), run_s)
        sweep.append(tr.write_latency_percentiles((99,))[99])
    out["utilizations"] = utils
    out["eager_p99_by_utilization"] = sweep

    c = out["claims"]
    c["eager_max_lower_than_lazy"] = eager_max < 0.95 * lazy_max
    c["lazy_sustainable_at_95"] = out["lazy_greedy_p99"] < 10.0
    c["eager_large_latency_at_95"] = out["eager_greedy_p99"] > \
        5 * max(out["lazy_greedy_p99"], 0.5)
    c["eager_ok_at_80"] = sweep[utils.index(0.8)] < \
        0.2 * sweep[-1] + 5.0
    save("fig25_27_secondary", out)
    return out
